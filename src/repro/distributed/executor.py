"""Sharded spectral inference: execute a ``ShardedNetworkPlan`` under
``shard_map``.

The paper's Alg 1 answers "reuse kernels or activations?" per layer; a
multi-chip mesh re-asks it one level up (DESIGN.md §4), and the
two-level autotuner (``autotune.autotune_layer_sharded``) answers with
a partitioning strategy per layer.  This module is the runtime for that
answer — one ``shard_map`` per sharded layer, strategies mixing freely
across layers because every layer's output returns to a well-defined
global layout:

  channel   shard d owns input channels [d*M/D, (d+1)*M/D).  The full
      activation is replicated; each shard slices its channels by
      ``axis_index``, runs the fused kernel on its SLICED operands
      (stacked on a leading device axis, ``P(axis)``) producing a
      partial spatial sum, and a ring all-reduce (``lax.psum``) — the
      2(D-1)/D output bytes the cost model charges — combines them.
      Bias+ReLU were DEFERRED at plan build (a partial sum through a
      ReLU is wrong); the executor applies the base epilogue post-psum.

  spatial   shard d owns a contiguous band of tile rows.  Each shard
      ships its LAST k-1 raw rows to its lower neighbour
      (``lax.ppermute`` — the (D-1)*(k-1)*W*C bytes the cost model
      charges), prepends the received halo (zeros on shard 0 — exactly
      the global 'same' zero padding), and runs the band kernel
      (``kernels.fused_spectral_conv.execute_band_plan``) whose
      geometry's ``pre_halo_h`` accounts for the received rows.  Band
      canvases concatenate on H (``P(None, None, axis, None)``) and the
      'same' crop runs ONCE, globally.

  replicate no shard_map at all: the base plan executes as on a single
      device.  Also the terminal rung of the sharded degradation ladder
      (``resilience.harden_sharded_plan``) — any layer that cannot run
      its fused shard kernels falls back here, a uniform plan-level
      decision, so no device is ever left blocked in a collective.

Every collective runs with ``check_rep=False`` — the bodies launch
Pallas kernels, which carry no replication rule.  The shard-scoped
fault site ``shard_tables`` is consulted HOST-SIDE (operand staging),
never inside a shard_map body: per-device python control flow does not
exist there (one trace serves all devices), and host-side is precisely
what turns an injected shard fault into a structured error *before*
any device enters a collective.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.core import resilience as res
from repro.core import spectral as spec
from repro.distributed import sharding as shd

Array = jax.Array


def _check_mesh(slp, mesh, axis: str) -> None:
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh axes {mesh.axis_names} lack the plan's "
                         f"shard axis {axis!r}")
    size = mesh.shape[axis]
    if size != slp.n_shards:
        raise ValueError(
            f"layer {slp.base.layer.name}: plan was built for "
            f"{slp.n_shards} shards but mesh axis {axis!r} has {size} "
            f"devices — rebuild the plan for this mesh "
            f"(plans never port across topologies; see plan_cache_key)")


def _stage_shard_tables(slp, strategy: str):
    """Host-side staging of per-shard Alg-2 tables with the shard-scoped
    fault site applied (check + corrupt) — the one place a single
    shard's tables can fail or rot before the collective launches."""
    name = slp.base.layer.name
    staged = []
    for d, sh in enumerate(slp.shards):
        res.fault_check("shard_tables", layer=name, shard=d,
                        strategy=strategy)
        tb = sh.tables
        if tb is not None:
            tb = res.fault_corrupt("shard_tables", tb, layer=name,
                                   shard=d, strategy=strategy)
        staged.append(tb)
    return staged


def _execute_spatial(x: Array, slp, mesh, axis: str,
                     interpret: bool | None) -> Array:
    from repro.kernels.fused_spectral_conv import execute_band_plan

    base = slp.base
    geo = base.geo
    ov = geo.ksize - 1
    D = slp.n_shards
    band = slp.shards[0]
    staged = _stage_shard_tables(slp, "spatial")
    band = dataclasses.replace(band, tables=staged[0])
    hb = band.geo.n_tiles_h * geo.tile          # raw rows per shard
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, D * hb - x.shape[2]), (0, 0)))

    def body(xb):
        # ship my last k-1 raw rows DOWN the mesh; shard 0's halo stays
        # zero — identical to the global 'same' zero padding.
        halo = jax.lax.ppermute(
            xb[:, :, -ov:, :], axis,
            [(i, i + 1) for i in range(D - 1)])
        x_ext = jnp.concatenate([halo, xb], axis=2)
        return execute_band_plan(x_ext, band, interpret=interpret)

    sp_ = shd.spectral_specs("spatial", axis)
    f = shard_map(body, mesh=mesh, in_specs=sp_["x"],
                  out_specs=sp_["out"], check_rep=False)
    canvas = f(xp)                               # [B, N, D*hb, w_pad]
    return spec.crop_canvas_same(canvas, geo)


def _execute_channel(x: Array, slp, mesh, axis: str,
                     interpret: bool | None) -> Array:
    from repro.core.plan import PlanTables
    from repro.kernels.fused_spectral_conv import execute_layer_plan

    base = slp.base
    shards = slp.shards
    mloc = shards[0].layer.c_in
    staged = _stage_shard_tables(slp, "channel")
    wr = jnp.stack([sh.wr for sh in shards])     # [D, Fa, N, Mloc]
    wi = jnp.stack([sh.wi for sh in shards])
    tabs: tuple[Array, ...] = ()
    if staged[0] is not None:
        tabs = tuple(jnp.stack([jnp.asarray(getattr(tb, f))
                                for tb in staged])
                     for f in ("idx", "sel", "vr", "vi"))

    def body(xf, wrd, wid, *tb):
        i = jax.lax.axis_index(axis)
        xloc = jax.lax.dynamic_slice_in_dim(xf, i * mloc, mloc, 1)
        lp = dataclasses.replace(
            shards[0], wr=wrd[0], wi=wid[0],
            tables=PlanTables(*(t[0] for t in tb)) if tb else None)
        y = execute_layer_plan(xloc, lp, interpret=interpret)
        return jax.lax.psum(y, axis)

    sp_ = shd.spectral_specs("channel", axis)
    f = shard_map(
        body, mesh=mesh,
        in_specs=(sp_["x"],) + (sp_["operand"],) * (2 + len(tabs)),
        out_specs=sp_["out"], check_rep=False)
    y = f(x, wr, wi, *tabs)
    return res._spatial_epilogue(y, base)        # deferred bias+ReLU


def execute_sharded_layer(x: Array, slp, mesh, *,
                          axis: str = shd.SPECTRAL_AXIS,
                          interpret: bool | None = None) -> Array:
    """Run one conv layer of a ``ShardedNetworkPlan`` on ``mesh``.

    Dispatches on ``slp.strategy`` (see module doc).  The output is
    always the full [B, N, H_out, W_out] activation in the global
    layout, so consecutive layers may use different strategies.
    Pooling stays with the caller (it is spatial and global), exactly
    as for ``resilience.execute_planned_layer``.
    """
    if slp.strategy == "replicate" or not slp.shards:
        return res.execute_planned_layer(x, slp.base,
                                         interpret=interpret)
    _check_mesh(slp, mesh, axis)
    if slp.strategy == "spatial":
        return _execute_spatial(x, slp, mesh, axis, interpret)
    if slp.strategy == "channel":
        return _execute_channel(x, slp, mesh, axis, interpret)
    raise ValueError(f"unknown shard strategy {slp.strategy!r}")


def _pool(x: Array) -> Array:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def forward_spectral_sharded(params: dict, splan, x: Array, *,
                             mesh: Any | None = None,
                             interpret: bool | None = None) -> Array:
    """Sharded analogue of ``models.cnn.forward_spectral``.

    Walks the ``ShardedNetworkPlan`` layer by layer through
    ``execute_sharded_layer`` (strategies mix freely), pools where the
    BASE plan says to, and runs the FC head replicated — the paper's
    CPU-side stage, a few matmuls XLA replicates trivially.  ``mesh``
    defaults to ``launch.mesh.make_spectral_mesh(splan.n_shards,
    splan.axis)``.
    """
    if mesh is None:
        from repro.launch.mesh import make_spectral_mesh
        mesh = make_spectral_mesh(splan.n_shards, splan.axis)
    for slp in splan.layers:
        x = execute_sharded_layer(x, slp, mesh, axis=splan.axis,
                                  interpret=interpret)
        if slp.base.epilogue.pool:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]
