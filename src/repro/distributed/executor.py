"""Sharded spectral inference: execute a ``ShardedNetworkPlan`` under
``shard_map``.

The paper's Alg 1 answers "reuse kernels or activations?" per layer; a
multi-chip mesh re-asks it one level up (DESIGN.md §4), and the
two-level autotuner (``autotune.autotune_layer_sharded``) answers with
a partitioning strategy per layer.  This module is the runtime for that
answer — one ``shard_map`` per sharded layer, strategies mixing freely
across layers because every layer's output returns to a well-defined
global layout:

  channel   shard d owns input channels [d*M/D, (d+1)*M/D).  The full
      activation is replicated; each shard slices its channels by
      ``axis_index``, runs the fused kernel on its SLICED operands
      (stacked on a leading device axis, ``P(axis)``) producing a
      partial spatial sum, and a ring all-reduce (``lax.psum``) — the
      2(D-1)/D output bytes the cost model charges — combines them.
      Bias+ReLU were DEFERRED at plan build (a partial sum through a
      ReLU is wrong); the executor applies the base epilogue post-psum.

  spatial   shard d owns a contiguous band of tile rows.  Each shard
      ships its LAST k-1 raw rows to its lower neighbour
      (``lax.ppermute`` — the (D-1)*(k-1)*W*C bytes the cost model
      charges), prepends the received halo (zeros on shard 0 — exactly
      the global 'same' zero padding), and runs the band kernel
      (``kernels.fused_spectral_conv.execute_band_plan``) whose
      geometry's ``pre_halo_h`` accounts for the received rows.  Band
      canvases concatenate on H (``P(None, None, axis, None)``) and the
      'same' crop runs ONCE, globally.

  replicate no shard_map at all: the base plan executes as on a single
      device.  Also the terminal rung of the sharded degradation ladder
      (``resilience.harden_sharded_plan``) — any layer that cannot run
      its fused shard kernels falls back here, a uniform plan-level
      decision, so no device is ever left blocked in a collective.

Every collective runs with ``check_rep=False`` — the bodies launch
Pallas kernels, which carry no replication rule.  The shard-scoped
fault site ``shard_tables`` is consulted HOST-SIDE (operand staging),
never inside a shard_map body: per-device python control flow does not
exist there (one trace serves all devices), and host-side is precisely
what turns an injected shard fault into a structured error *before*
any device enters a collective.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.core import resilience as res
from repro.core import spectral as spec
from repro.distributed import sharding as shd

Array = jax.Array


def _check_mesh(slp, mesh, axis: str) -> None:
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh axes {mesh.axis_names} lack the plan's "
                         f"shard axis {axis!r}")
    size = mesh.shape[axis]
    if size != slp.n_shards:
        raise ValueError(
            f"layer {slp.base.layer.name}: plan was built for "
            f"{slp.n_shards} shards but mesh axis {axis!r} has {size} "
            f"devices — rebuild the plan for this mesh "
            f"(plans never port across topologies; see plan_cache_key)")


def _stage_shard_tables(slp, strategy: str):
    """Host-side staging of per-shard Alg-2 tables with the shard-scoped
    fault site applied (check + corrupt) — the one place a single
    shard's tables can fail or rot before the collective launches."""
    name = slp.base.layer.name
    staged = []
    for d, sh in enumerate(slp.shards):
        res.fault_check("shard_tables", layer=name, shard=d,
                        strategy=strategy)
        tb = sh.tables
        if tb is not None:
            tb = res.fault_corrupt("shard_tables", tb, layer=name,
                                   shard=d, strategy=strategy)
        staged.append(tb)
    return staged


def _defer_epilogue(lp):
    """A copy of ``lp`` whose in-kernel ReLU is suppressed (and any
    residual marker cleared): residual DAG nodes apply
    ``relu(y + shortcut)`` AFTER the collective, so the kernel must
    flush the bias-only activation."""
    return dataclasses.replace(
        lp, epilogue=dataclasses.replace(lp.epilogue, relu=False,
                                         residual=None))


def _execute_spatial(x: Array, slp, mesh, axis: str,
                     interpret: bool | None,
                     defer_relu: bool = False) -> Array:
    from repro.kernels.fused_spectral_conv import execute_band_plan

    base = slp.base
    geo = base.geo
    ov = geo.ksize - 1
    D = slp.n_shards
    band = slp.shards[0]
    staged = _stage_shard_tables(slp, "spatial")
    band = dataclasses.replace(band, tables=staged[0])
    if defer_relu:
        band = _defer_epilogue(band)
    hb = band.geo.n_tiles_h * geo.tile          # raw rows per shard
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, D * hb - x.shape[2]), (0, 0)))

    def body(xb):
        # ship my last k-1 raw rows DOWN the mesh; shard 0's halo stays
        # zero — identical to the global 'same' zero padding.
        halo = jax.lax.ppermute(
            xb[:, :, -ov:, :], axis,
            [(i, i + 1) for i in range(D - 1)])
        x_ext = jnp.concatenate([halo, xb], axis=2)
        return execute_band_plan(x_ext, band, interpret=interpret)

    sp_ = shd.spectral_specs("spatial", axis)
    f = shard_map(body, mesh=mesh, in_specs=sp_["x"],
                  out_specs=sp_["out"], check_rep=False)
    canvas = f(xp)                               # [B, N, D*hb, w_pad]
    return spec.crop_canvas_same(canvas, geo)


def _execute_channel(x: Array, slp, mesh, axis: str,
                     interpret: bool | None,
                     defer_relu: bool = False) -> Array:
    from repro.core.plan import PlanTables
    from repro.kernels.fused_spectral_conv import execute_layer_plan

    base = slp.base
    shards = slp.shards
    mloc = shards[0].layer.c_in
    staged = _stage_shard_tables(slp, "channel")
    wr = jnp.stack([sh.wr for sh in shards])     # [D, Fa, N, Mloc]
    wi = jnp.stack([sh.wi for sh in shards])
    tabs: tuple[Array, ...] = ()
    if staged[0] is not None:
        tabs = tuple(jnp.stack([jnp.asarray(getattr(tb, f))
                                for tb in staged])
                     for f in ("idx", "sel", "vr", "vi"))

    def body(xf, wrd, wid, *tb):
        i = jax.lax.axis_index(axis)
        xloc = jax.lax.dynamic_slice_in_dim(xf, i * mloc, mloc, 1)
        lp = dataclasses.replace(
            shards[0], wr=wrd[0], wi=wid[0],
            tables=PlanTables(*(t[0] for t in tb)) if tb else None)
        y = execute_layer_plan(xloc, lp, interpret=interpret)
        return jax.lax.psum(y, axis)

    sp_ = shd.spectral_specs("channel", axis)
    f = shard_map(
        body, mesh=mesh,
        in_specs=(sp_["x"],) + (sp_["operand"],) * (2 + len(tabs)),
        out_specs=sp_["out"], check_rep=False)
    y = f(x, wr, wi, *tabs)
    # deferred bias(+ReLU) — a partial sum through a ReLU is wrong
    epi = _defer_epilogue(base) if defer_relu else base
    return res._spatial_epilogue(y, epi)


def execute_sharded_layer(x: Array, slp, mesh, *,
                          axis: str = shd.SPECTRAL_AXIS,
                          interpret: bool | None = None,
                          defer_relu: bool = False) -> Array:
    """Run one conv layer of a ``ShardedNetworkPlan`` on ``mesh``.

    Dispatches on ``slp.strategy`` (see module doc).  The output is
    always the full [B, N, H_out, W_out] activation in the global
    layout, so consecutive layers may use different strategies.
    Pooling and stride subsampling stay with the caller (they are
    spatial and global), exactly as for
    ``resilience.execute_planned_layer``.

    ``defer_relu`` suppresses the epilogue ReLU wherever it would run
    (in-kernel, band kernel, or post-psum) and returns the bias-only
    activation — the residual DAG walk applies ``relu(y + shortcut)``
    after the collective.
    """
    if slp.strategy == "replicate" or not slp.shards:
        base = _defer_epilogue(slp.base) if defer_relu else slp.base
        return res.execute_planned_layer(x, base, interpret=interpret)
    _check_mesh(slp, mesh, axis)
    if slp.strategy == "spatial":
        return _execute_spatial(x, slp, mesh, axis, interpret,
                                defer_relu)
    if slp.strategy == "channel":
        return _execute_channel(x, slp, mesh, axis, interpret,
                                defer_relu)
    raise ValueError(f"unknown shard strategy {slp.strategy!r}")


def _pool(x: Array, kind: str = "max") -> Array:
    b, c, h, w = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :, :h2 * 2, :w2 * 2].reshape(b, c, h2, 2, w2, 2)
    return x.max(axis=(3, 5)) if kind == "max" else x.mean(axis=(3, 5))


def forward_spectral_sharded(params: dict, splan, x: Array, *,
                             mesh: Any | None = None,
                             interpret: bool | None = None) -> Array:
    """Sharded analogue of ``models.cnn.forward_spectral``.

    Walks the BASE plan's execution DAG (ISSUE 10) node by node:
    conv nodes run through ``execute_sharded_layer`` (strategies mix
    freely), pool nodes run globally, stride-2 outputs subsample after
    the collective, and residual edges add in the global layout —
    in-kernel (fused epilogue) only on replicated residual-FUSED
    layers, as a post-collective ``relu(y + shortcut)`` everywhere
    else.  The FC head runs replicated — the paper's CPU-side stage, a
    few matmuls XLA replicates trivially.  ``mesh`` defaults to
    ``launch.mesh.make_spectral_mesh(splan.n_shards, splan.axis)``.
    """
    if mesh is None:
        from repro.launch.mesh import make_spectral_mesh
        mesh = make_spectral_mesh(splan.n_shards, splan.axis)
    from repro.core.plan import graph_sink
    graph = splan.base.execution_graph
    out_id = graph_sink(graph)
    refs: dict[str, int] = {out_id: 1}
    for node in graph:
        for src in (node.inputs[0], node.residual_from):
            if src is not None:
                refs[src] = refs.get(src, 0) + 1
    acts: dict[str, Array] = {"input": x}
    for node in graph:
        src = acts[node.inputs[0]]
        if node.kind == "pool":
            y = _pool(src, node.pool)
        else:
            slp = splan.layers[node.layer_index]
            base = slp.base
            stride = getattr(base.layer, "stride", 1)
            sc = (acts[node.residual_from]
                  if node.residual_from is not None else None)
            replicated = slp.strategy == "replicate" or not slp.shards
            if sc is None:
                y = execute_sharded_layer(src, slp, mesh,
                                          axis=splan.axis,
                                          interpret=interpret)
                y = y[:, :, ::stride, ::stride]
            elif (replicated
                  and getattr(base, "backend", "fused") == "fused"
                  and getattr(base.epilogue, "residual", None)
                  == "fused"):
                # replicated residual-FUSED node: the shortcut rides
                # the kernel's epilogue flush (stride 1 by invariant)
                y = res.execute_planned_layer(src, base,
                                              interpret=interpret,
                                              shortcut=sc)
            else:
                y = execute_sharded_layer(src, slp, mesh,
                                          axis=splan.axis,
                                          interpret=interpret,
                                          defer_relu=True)
                y = y[:, :, ::stride, ::stride] + sc
                if node.relu:
                    y = jax.nn.relu(y)
        acts[node.id] = y
        for s in (node.inputs[0], node.residual_from):
            if s is not None:
                refs[s] -= 1
                if refs[s] == 0:
                    acts.pop(s, None)
    x = acts[out_id]
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"])
    x = jax.nn.relu(x @ params["fc2"])
    return x @ params["fc3"]
