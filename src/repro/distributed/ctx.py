"""Trace-time sharding-constraint context for model code.

Model code is mesh-agnostic; when the launcher traces a step under a
``ShardCtx``, `constrain(x, kind)` pins intermediate activations to the
intended layout so XLA's sharding propagation cannot drift into
reshuffling all-to-alls between layers (one of the §Perf findings).
Outside a context (unit tests, single-host runs) it is a no-op.

Kinds:
  residual      [B, S, d]  -> P(batch, seq?, None)  — block boundaries;
                with ``seq_parallel`` the sequence dim is sharded over
                'model' so the boundary collective becomes
                reduce-scatter + all-gather instead of all-reduce
  moe_dispatch  [G, E, C, d] -> P(batch, 'model', None, None)
  moe_combine   [G, S, d]  -> P(batch, None, None)
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    batch_axes: tuple[str, ...]
    seq_parallel: bool = False
    model_axis: str = "model"
    moe_ep: bool = False                 # shard_map expert parallelism
    mesh: object = None                  # required when moe_ep
    fsdp_axes: tuple[str, ...] = ()


def current() -> ShardCtx | None:
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use(ctx: ShardCtx):
    prev = current()
    _state.ctx = ctx
    try:
        yield
    finally:
        _state.ctx = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    ctx = current()
    if ctx is None:
        return x
    ba = ctx.batch_axes
    if kind == "residual":
        seq = ctx.model_axis if ctx.seq_parallel else None
        spec = P(ba, seq, *([None] * (x.ndim - 2)))
    elif kind == "moe_dispatch":
        spec = P(ba, ctx.model_axis, *([None] * (x.ndim - 2)))
    elif kind == "moe_combine":
        spec = P(ba, *([None] * (x.ndim - 1)))
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, spec)
