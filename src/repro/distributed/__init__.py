"""distributed subpackage."""
