"""Mesh-scale dataflow planner — the paper's Alg 1 re-targeted at sharding.

For every (arch, mesh, shape) cell, enumerate candidate strategies
(which tensor class is *reused* on-chip vs *streamed* over the network):

  TP            weights resident per model shard      (Flow #1 analogue)
  TP+FSDP(d)    weights also sharded over 'data',
                all-gathered per layer                (Flow #2 analogue)
  TP+FSDP(d,p)  ... and over 'pod'

x optimizer in {adamw, adafactor}.  Each candidate is costed with a
closed-form HBM-residency and collective-traffic model (the Eq 12/13
analogue, TPU v5e constants), infeasible ones (> HBM per chip) are
rejected, and the minimum-collective-traffic feasible plan wins —
exactly the structure of Alg 1 (search, capacity constraint, minimize
bandwidth).  The dry-run's HLO-parsed collective bytes validate the
model (EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import dataclasses
import math

from repro.configs import ShapeConfig
from repro.core.dataflow import TPU_HBM_GBPS, TPU_ICI_GBPS
from repro.distributed.sharding import ShardingPlan
from repro.models.config import ModelConfig

HBM_PER_CHIP = 16 * 2 ** 30           # v5e: 16 GiB


@dataclasses.dataclass(frozen=True)
class PlanCost:
    plan: ShardingPlan
    param_bytes_per_chip: float
    opt_bytes_per_chip: float
    act_bytes_per_chip: float
    total_bytes_per_chip: float
    collective_bytes_per_step: float   # per chip
    fits: bool

    def summary(self) -> str:
        return (f"fsdp={self.plan.fsdp_axes if self.plan.fsdp else '-'} "
                f"opt={self.plan.optimizer} "
                f"mem={self.total_bytes_per_chip/2**30:.2f}GiB "
                f"coll={self.collective_bytes_per_step/2**30:.2f}GiB/step "
                f"fits={self.fits}")


def _bytes_per_param(dtype: str) -> int:
    return 2 if dtype == "bfloat16" else 4


def _mesh_sizes(mesh_shape: dict[str, int]) -> tuple[int, int, int]:
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    return model, data, model * data


def estimate(cfg: ModelConfig, shape: ShapeConfig,
             mesh_shape: dict[str, int], plan: ShardingPlan) -> PlanCost:
    model, data, chips = _mesh_sizes(mesh_shape)
    if not plan.tp:
        # pure weight-streaming: no tensor-parallel axis; tokens shard
        # over plan.batch_axes, weights over plan.fsdp_axes
        model = 1
        data = 1
        for ax in plan.batch_axes:
            data *= mesh_shape.get(ax, 1)
    n_params = cfg.param_count()
    bpp = _bytes_per_param(cfg.param_dtype)
    fsdp_ways = 1
    if plan.fsdp:
        for ax in plan.fsdp_axes:
            fsdp_ways *= mesh_shape.get(ax, 1)

    shard_ways = model * fsdp_ways
    param_bytes = n_params * bpp / shard_ways

    train = shape.kind == "train"
    if train:
        grad_bytes = param_bytes
        opt_mult = 8.0 if plan.optimizer == "adamw" else 0.2
        opt_bytes = n_params * opt_mult / shard_ways
    else:
        grad_bytes = 0.0
        opt_bytes = 0.0

    # activations: with remat ~ (2 residual streams + attn workspace) per
    # layer boundary; without remat all block internals are live.
    tokens_per_chip = shape.seq_len * shape.global_batch / max(data, 1)
    act_per_token_layer = cfg.d_model * 2      # bf16 residual
    live_factor = 4.0 if plan.remat else 24.0
    if shape.kind == "decode":
        act_bytes = tokens_per_chip * cfg.d_model * 2 * 8 / shape.seq_len
        # decode activations are per-token; KV cache dominates instead
        kv_len = min(shape.seq_len, cfg.window or shape.seq_len)
        if cfg.family in ("xlstm", "hybrid"):
            kv_len = min(kv_len, 4096)          # bounded recurrent state
        layers = cfg.n_layers if cfg.family not in ("hybrid",) else \
            math.ceil(cfg.n_layers / cfg.attn_every)
        kv_bytes = (2 * layers * cfg.n_kv_heads * cfg.hd * kv_len
                    * shape.global_batch * 2) / chips
        act_bytes += kv_bytes
    else:
        act_bytes = (tokens_per_chip * act_per_token_layer
                     * cfg.n_layers * live_factor / max(model, 1))

    total = param_bytes + grad_bytes + opt_bytes + act_bytes

    # collective traffic per chip per step (bytes on the wire):
    coll = 0.0
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "decode":
        tokens = shape.global_batch
    act_row = tokens * cfg.d_model * 2 / max(data, 1)
    # TP: 2 all-reduces per layer over activations (ring: 2x bytes)
    if model > 1:
        coll += 2 * cfg.n_layers * 2 * act_row * (model - 1) / model
    if plan.fsdp and train:
        # per-layer weight all-gather (fwd+bwd) + grad reduce-scatter
        coll += 3 * n_params * bpp / model * (fsdp_ways - 1) / fsdp_ways
    elif plan.fsdp:
        # inference: weights all-gathered once per step
        coll += n_params * bpp / model * (fsdp_ways - 1) / fsdp_ways
    if train and data > 1 and not plan.fsdp:
        # DP gradient all-reduce
        coll += 2 * n_params * bpp / model * (data - 1) / data
    if cfg.family == "moe" and model > 1:
        # dispatch+combine all-to-alls over the expert axis
        coll += 2 * tokens * cfg.d_model * 2 * cfg.top_k / max(data, 1)

    fits = total <= HBM_PER_CHIP
    return PlanCost(plan, param_bytes, opt_bytes, act_bytes, total,
                    coll, fits)


def candidates(cfg: ModelConfig, mesh_shape: dict[str, int],
               shape: ShapeConfig) -> list[ShardingPlan]:
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh_shape)
    all_axes = tuple(mesh_shape)
    total = 1
    for v in mesh_shape.values():
        total *= v
    seq_shard = shape.kind == "decode" and shape.global_batch == 1
    opts = ["adamw", "adafactor"] if shape.kind == "train" else ["adamw"]
    outs = []
    for fsdp_axes in [(), ("data",), batch_axes]:
        for opt in opts:
            outs.append(ShardingPlan(
                batch_axes=batch_axes,
                fsdp=bool(fsdp_axes), fsdp_axes=tuple(fsdp_axes),
                seq_shard=seq_shard, optimizer=opt, remat=cfg.remat))
    # pure weight-streaming (no TP) — the Flow-#2 answer: reuse
    # activations locally, stream kernels over the network.  Offered for
    # the dense transformer family only: MoE needs the model axis for
    # expert memory, and the recurrent families (hybrid/xlstm) reshard
    # badly without TP (measured in EXPERIMENTS.md §Perf Cell B) — their
    # validated plan stays TP.
    # ... and only when every chip gets >= 1 sequence: with
    # global_batch % chips != 0 the idle model axis would replicate
    # compute (measured in §Perf Cell B iter 1).
    if cfg.family == "dense" and shape.global_batch % total == 0:
        for opt in opts:
            outs.append(ShardingPlan(
                batch_axes=all_axes, fsdp=True, fsdp_axes=all_axes,
                seq_shard=seq_shard, optimizer=opt, remat=cfg.remat,
                tp=False))
    # dedupe
    seen, uniq = set(), []
    for p in outs:
        key = (p.fsdp_axes, p.optimizer, p.tp)
        if key not in seen:
            seen.add(key)
            uniq.append(p)
    return uniq


def plan_cell(cfg: ModelConfig, shape: ShapeConfig,
              mesh_shape: dict[str, int]
              ) -> tuple[PlanCost, list[PlanCost]]:
    """Alg-1 loop: all candidates costed, feasible min-traffic selected."""
    costs = [estimate(cfg, shape, mesh_shape, p)
             for p in candidates(cfg, mesh_shape, shape)]
    feasible = [c for c in costs if c.fits]
    pool = feasible or costs            # report best-effort if none fit
    # min collective traffic; prefer plain AdamW on ties (Adafactor is the
    # fallback when moments don't fit), then smaller footprint
    best = min(pool, key=lambda c: (not c.fits,
                                    c.collective_bytes_per_step,
                                    c.plan.optimizer != "adamw",
                                    c.total_bytes_per_chip))
    return best, costs


# ---------------------------------------------------------------------------
# Spectral-CNN cell (ISSUE 9): the conv stack's two-level Alg 1
# ---------------------------------------------------------------------------

def spectral_plan_cell(layers=None, fft_size: int = 8, alpha=4.0, *,
                       n_shards: int, batch: int = 1,
                       **autotune_kwargs) -> dict:
    """Plan one spectral-CNN (mesh, shape) cell: per-layer partitioning
    via the two-level autotuner plus the whole-network roll-up the
    planner reports for every other family.

    Unlike the transformer cells above — one strategy for the whole
    model — the spectral stack picks per LAYER (the paper's Alg-1
    granularity carried up a level): early convs with large canvases
    and few channels go 'spatial', late channel-heavy convs go
    'channel'.  Returns per-layer tunings plus network totals in the
    same spirit as ``PlanCost``: worst per-chip HBM footprint, total
    ICI bytes on the wire, and the summed two-level latency objective.
    """
    from repro.core.autotune import autotune_network_sharded
    from repro.core.dataflow import VGG16_LAYERS

    layers = list(VGG16_LAYERS if layers is None else layers)
    tunings = autotune_network_sharded(
        layers, fft_size, alpha, n_shards=n_shards, batch=batch,
        **autotune_kwargs)
    strategies = {n: t.strategy for n, t in tunings.items()}
    return {
        "n_shards": n_shards,
        "tunings": tunings,
        "strategies": strategies,
        "n_spatial": sum(s == "spatial" for s in strategies.values()),
        "n_channel": sum(s == "channel" for s in strategies.values()),
        "n_replicate": sum(s == "replicate" for s in strategies.values()),
        "per_chip_hbm_bytes": max(t.per_chip_hbm_bytes
                                  for t in tunings.values()),
        "ici_bytes_total": sum(t.ici_bytes for t in tunings.values()),
        "sharded_s_total": sum(t.sharded_s for t in tunings.values()),
        "ici_s_total": sum(t.ici_s for t in tunings.values()),
    }
