"""resnet18-spectral: ResNet-18-style residual DAG preset (ISSUE 10).

Stem conv + max-pool, then stages of two identity blocks each (two 3x3
convs per block, shortcut over the block), stage transitions via a
stride-2 3x3 conv that doubles the channels, and a 2x2 avg-pool before
the FC head.  All convs are 3x3 'same' — the spectral overlap-save path
only supports the paper's 3x3/K=8 geometry, so the classic 7x7 stem and
1x1 projection shortcuts are replaced by a 3x3 stem and
projection-free blocks (every shortcut is an identity edge whose shape
matches the block output exactly, which is what the residual-FUSED
epilogue requires).

``CONFIG`` is the full-scale 224x224 preset; ``SMOKE`` the CI-sized
variant every DAG parity test and the gated BENCH ``resnet`` column
run (2 stages, 8/16 channels, 32x32 input — stride-2, max-pool,
avg-pool and four residual-fused nodes included).
"""

from repro.core.dataflow import ConvLayer, NodeSpec
from repro.models.cnn import SpectralCNNConfig


def resnet18_config(*, name: str = "resnet18-spectral",
                    image_size: int = 224, width: int = 64,
                    stage_mults: tuple[int, ...] = (1, 2, 4, 8),
                    blocks_per_stage: int = 2,
                    n_classes: int = 1000, fc_dim: int = 512,
                    alpha: float = 4.0) -> SpectralCNNConfig:
    """Build a ResNet-18-style residual ``SpectralCNNConfig``.

    Stage s uses ``width * stage_mults[s]`` channels; every stage after
    the first opens with a stride-2 downsample conv.  Node ids:
    ``stem``, ``stem:pool`` (max), ``s<i>down``, ``s<i>b<j>a`` /
    ``s<i>b<j>b`` (the b-conv carries the residual edge back to the
    block input), ``head:pool`` (avg).
    """
    layers = [ConvLayer("stem", 3, width * stage_mults[0],
                        image_size, image_size)]
    nodes = [NodeSpec(id="stem"),
             NodeSpec(id="stem:pool", kind="pool", inputs=("stem",))]
    prev, h = "stem:pool", image_size // 2
    c = width * stage_mults[0]
    for i, mult in enumerate(stage_mults, start=1):
        c_out = width * mult
        if i > 1:
            down = f"s{i}down"
            layers.append(ConvLayer(down, c, c_out, h, h, stride=2))
            nodes.append(NodeSpec(id=down, inputs=(prev,)))
            prev, h, c = down, -(-h // 2), c_out
        for b in range(1, blocks_per_stage + 1):
            block_in = prev
            a, bb = f"s{i}b{b}a", f"s{i}b{b}b"
            layers.append(ConvLayer(a, c, c, h, h))
            nodes.append(NodeSpec(id=a, inputs=(prev,)))
            layers.append(ConvLayer(bb, c, c, h, h))
            nodes.append(NodeSpec(id=bb, inputs=(a,),
                                  residual_from=block_in))
            prev = bb
    nodes.append(NodeSpec(id="head:pool", kind="pool", pool="avg",
                          inputs=(prev,)))
    return SpectralCNNConfig(
        name=name, layers=tuple(layers), alpha=alpha,
        n_classes=n_classes, image_size=image_size, fc_dim=fc_dim,
        pool_after=frozenset(), graph=tuple(nodes))


CONFIG = resnet18_config()

SMOKE = resnet18_config(
    name="resnet18-spectral-smoke", image_size=32, width=8,
    stage_mults=(1, 2), n_classes=10, fc_dim=32)
