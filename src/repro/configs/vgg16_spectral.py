"""vgg16-spectral: the paper's own target model (FPGA '20 S6.3).

224x224 input, K=8 spectral kernels, alpha=4 compression, P'=9, N'=64,
r=10 replicas.
"""

from repro.core.dataflow import ConvLayer
from repro.models.cnn import SpectralCNNConfig

CONFIG = SpectralCNNConfig()

_SMOKE_LAYERS = (
    ConvLayer("conv1_1", 3, 8, 32, 32),
    ConvLayer("conv1_2", 8, 8, 32, 32),
    ConvLayer("conv2_1", 8, 16, 16, 16),
    ConvLayer("conv2_2", 16, 16, 16, 16),
    ConvLayer("conv3_1", 16, 16, 8, 8),
    ConvLayer("conv3_2", 16, 16, 8, 8),
    ConvLayer("conv3_3", 16, 16, 8, 8),
    ConvLayer("conv4_1", 16, 16, 4, 4),
    ConvLayer("conv4_2", 16, 16, 4, 4),
    ConvLayer("conv4_3", 16, 16, 4, 4),
    ConvLayer("conv5_1", 16, 16, 2, 2),
    ConvLayer("conv5_2", 16, 16, 2, 2),
    ConvLayer("conv5_3", 16, 16, 2, 2),
)

SMOKE = SpectralCNNConfig(
    name="vgg16-spectral-smoke", layers=_SMOKE_LAYERS,
    image_size=32, n_classes=10, fc_dim=32)
