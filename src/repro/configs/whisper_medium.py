"""whisper-medium [audio]: enc-dec, conv frontend stubbed to frame embeds.

24L (per side) d_model=1024 16H (kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab=51865,
    norm="layernorm", mlp="gelu", frontend="frames", dec_train_len=448,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128, norm="layernorm", mlp="gelu",
    frontend="frames", dec_train_len=16,
)
