"""xlstm-350m [ssm]: sLSTM + mLSTM blocks (attention-free).

24L d_model=1024 4H d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
7:1 mLSTM:sLSTM ratio (sLSTM every 8th block).  d_ff=0: mixing blocks
carry their own up/down projections.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, slstm_every=8,
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="xlstm",
    n_layers=4, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=64, slstm_every=2,
)
