"""kimi-k2-1t-a32b [moe]: trillion-parameter 384-expert top-8 MoE.

61L d_model=7168 64H (GQA kv=8) d_ff(expert)=2048 vocab=163840
[arXiv:2501.kimi2; unverified / paper-table].  The memory-bound cell of
the assignment: the sharding planner must pick FSDP + factored optimizer
states (Adafactor) to fit 512 chips (DESIGN.md S4).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    n_experts=384, top_k=8, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="kimi-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=32, vocab=256, head_dim=8, n_experts=8, top_k=2,
)
