"""Architecture registry: one module per assigned architecture.

``get_config(arch)`` returns the full published config;
``get_smoke_config(arch)`` a reduced same-family config for CPU tests.
``SHAPES`` are the assigned input-shape cells.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "zamba2-7b",
    "whisper-medium",
    "qwen3-8b",
    "yi-6b",
    "smollm-135m",
    "h2o-danube-1.8b",
    "moonshot-v1-16b-a3b",
    "kimi-k2-1t-a32b",
    "chameleon-34b",
    "xlstm-350m",
)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs bounded-state decode: run only for SSM/hybrid/SWA archs
# (DESIGN.md §Arch-applicability), skip pure full-attention archs.
LONG_CONTEXT_ARCHS = {"zamba2-7b", "h2o-danube-1.8b", "xlstm-350m"}


def _module(arch: str):
    return importlib.import_module(
        "repro.configs." + arch.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ModelConfig:
    if arch == "vgg16-spectral":
        raise ValueError("use repro.models.cnn.SpectralCNNConfig")
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, with applicability filtering."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            skipped = (shape.name == "long_500k"
                       and arch not in LONG_CONTEXT_ARCHS)
            if include_skipped or not skipped:
                out.append((arch, shape.name, skipped))
    return out
