"""qwen3-8b [dense]: GQA + per-head qk RMS-norm.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936
[hf:Qwen/Qwen3-8B; hf].  head_dim=128, rope_theta=1e6.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=128, head_dim=16, qk_norm=True, rope_theta=1e6,
)
