"""moonshot-v1-16b-a3b [moe]: Moonlight-style 64-expert top-6 MoE.

48L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408 vocab=163840
[hf:moonshotai/Moonlight-16B-A3B; hf].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=32, vocab=128, n_experts=8, top_k=2,
)
