"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attn.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000
[arXiv:2401.16818; hf].  SWA window 4096 -> O(window) decode state,
so long_500k runs for this arch.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, window=4096,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="danube-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=128, window=16,
)
