"""chameleon-34b [vlm]: early-fusion token backbone (VQ frontend stub).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536
[arXiv:2405.09818; unverified].  Image tokens live in the same vocab
(early fusion); qk-norm + layernorm as in the release.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=65536, qk_norm=True, norm="layernorm",
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="chameleon-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab=256, qk_norm=True, norm="layernorm",
)
