"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified].  Shared transformer block applied every
6th backbone block (single shared parameter set — Zamba2's weight-sharing
trick; the released model alternates two shared blocks, simplification
noted in DESIGN.md).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm_state=64, ssm_heads=56, ssm_expand=2, ssm_chunk=128,
    attn_every=6, rope_theta=1e4,
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=128,
    ssm_state=8, ssm_heads=4, ssm_expand=2, ssm_chunk=8,
    attn_every=3,
)
